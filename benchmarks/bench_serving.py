"""Serving-tier benchmark: forecasts/s + p50/p99 latency under a replayed
Poisson request trace, swept over batch-bucket size and fp32-vs-int8.

Trace model (replayable by ``--seed``): ``--requests`` forecast requests
with exponential inter-arrivals at ``--rate``/s, drawn over ``--consumers``
synthetic buildings (first contact ships the raw history for routing +
normalization stats; later requests hit the engine's consumer cache).  The
replay is a single-server queue simulation with MEASURED service times:

* the simulated clock advances with the arrival process;
* the engine flushes when a bucket fills, or when the oldest queued request
  has waited ``--max-wait`` seconds of simulated time;
* each flush's service time is the measured wall time of the jitted batch
  (blocked on the result), so per-request latency = simulated queueing +
  measured compute, and p50/p99 reflect load, not just kernel speed.

Mid-replay the benchmark PUBLISHES a new model generation into every slot
(the FL-rounds-as-publisher path) — the engine must hot-swap without a
single new jit-cache entry; the zero-steady-state-recompile invariant is
asserted with the ``analysis/recompile.py`` cache probe on every config.

Roofline positioning (vs ``launch/roofline.py`` constants): a forecast is
``~2 · params · lookback`` FLOPs, so one TPU-v5e chip bounds throughput at
``197e12 / flops_per_forecast`` forecasts/s; the table's last column shows
achieved/bound.  On CPU this is a ceiling reference, not a target.

  PYTHONPATH=src:. python benchmarks/bench_serving.py
  PYTHONPATH=src:. python benchmarks/bench_serving.py --buckets 64,512 --rate 5000
  PYTHONPATH=src:. python benchmarks/bench_serving.py --smoke
"""
from __future__ import annotations

import argparse
from typing import Dict, List

import jax
import numpy as np

from repro.analysis import recompile
from repro.configs.base import ForecasterConfig
from repro.core import clustering
from repro.data import synthetic, windows
from repro.launch.mesh import PEAK_FLOPS
from repro.models import forecaster
from repro.serving import ClusterRouter, ModelRegistry, ServingEngine


def _build_registry(params, cfg, slots, wtype, key):
    reg = ModelRegistry()
    for s in slots:
        reg.publish(params, cfg, slot=s, generation=1, weights=wtype,
                    key=(jax.random.fold_in(key, s + 1)
                         if wtype == "int8" else None))
    return reg


def _make_swap(reg, wtype, slots, params2, cfg, key):
    """Publisher for the mid-replay hot-swap: generation 2 into every slot."""
    def swap():
        for s in slots:
            reg.publish(params2, cfg, slot=s, generation=2, weights=wtype,
                        key=(jax.random.fold_in(key, 100 + s)
                             if wtype == "int8" else None), if_newer=True)
    return swap


def _replay(engine: ServingEngine, histories, arrivals, order,
            max_wait: float, swap_at: int, publish_swap) -> Dict:
    """Single-server queue replay; returns latency/throughput stats."""
    L = engine.registry.handle(engine.registry.slots()[0]).cfg.lookback
    arrival_of: Dict[int, float] = {}
    first_contact = set()
    latencies: List[float] = []
    server_free = 0.0

    def _account(stats, clock):
        nonlocal server_free
        for fs in stats:
            start = max(server_free, clock)
            server_free = start + fs.wall_s
            for r in fs.requests:
                latencies.append(server_free - arrival_of.pop(id(r)))

    for i, (cid, t) in enumerate(zip(order, arrivals)):
        if i == swap_at:
            publish_swap()
        hist = None
        if cid not in first_contact:
            first_contact.add(int(cid))
            hist = histories[cid]
        req = engine.submit(int(cid), histories[cid][-L:], history=hist)
        arrival_of[id(req)] = t
        if engine.pending(req.slot) >= engine.max_batch:
            _account(engine.flush(req.slot), t)
        for s in engine.queued_slots():     # deadline: oldest member aged out
            head = engine.oldest(s)
            if head is not None and t - arrival_of[id(head)] > max_wait:
                _account(engine.flush(s), t)
    _account(engine.flush(), float(arrivals[-1]) if len(arrivals) else 0.0)

    lat = np.asarray(latencies)
    return {
        "n": len(lat),
        "fps": len(lat) / max(engine.stats.busy_s, 1e-12),
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "fill": engine.stats.fill(),
        "flushes": engine.stats.flushes,
        "swaps": engine.stats.swaps_seen,
    }


def main(requests=2000, consumers=64, rate=2000.0, buckets="64,256",
         weights="fp32,int8", clusters=2, days=14, hidden=64,
         max_wait=0.02, seed=0, state="CA", smoke=False):
    if smoke:
        requests, consumers, rate = 300, 16, 500.0
        buckets, days, hidden = "16", 7, 16
    cfg = ForecasterConfig(hidden_dim=hidden)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 11]))
    root = jax.random.fold_in(jax.random.PRNGKey(seed), 17)
    params = forecaster.init_forecaster(jax.random.fold_in(root, 0), cfg)
    params2 = jax.tree.map(lambda a: a * 1.001, params)   # the hot-swap gen

    print(f"[serving] {requests} requests over {consumers} consumers, "
          f"Poisson rate {rate:.0f}/s, clusters={clusters or 'off'}, "
          f"max-wait {max_wait*1e3:.0f} ms")
    histories = synthetic.generate_buildings(
        state, list(range(consumers)), days=days)
    if clusters > 1:
        z = windows.daily_average_vector(histories, days=days)
        cents, _, _ = clustering.kmeans(z, clusters, seed=seed)
        router = ClusterRouter(cents)
        slots = list(range(clusters))
    else:
        router = ClusterRouter(None)
        slots = [-1]

    arrivals = np.cumsum(rng.exponential(1.0 / rate, requests))
    order = rng.integers(0, consumers, requests)

    flops_fc = 2.0 * cfg.num_params() * cfg.lookback
    bound = PEAK_FLOPS / flops_fc
    rows = []
    for wtype in [w.strip() for w in str(weights).split(",") if w.strip()]:
        for b in [int(x) for x in str(buckets).split(",")]:
            # ---- steady-state guard: post-warmup replay slices (with a
            # hot-swap in the middle) must add ZERO jit-cache entries
            reg = _build_registry(params, cfg, slots, wtype, root)
            eng = ServingEngine(reg, router, max_batch=b,
                                min_bucket=min(8, b), auto_flush=False)
            eng.warmup()
            swap = _make_swap(reg, wtype, slots, params2, cfg, root)

            def step(i, eng=eng, swap=swap):
                return _replay(eng, histories, arrivals[:64], order[:64],
                               max_wait, 32 if i == 1 else -1, swap)

            rep = recompile.count_recompiles(step, steps=2,
                                             cache_size=eng.jit_cache_size)
            if not rep.ok:
                raise AssertionError(
                    f"serving steady state recompiled ({wtype}, bucket {b}):"
                    f" {rep.render()}")

            # ---- timed replay on a fresh engine (the jit bodies are
            # module-level, so the compiled traces carry over)
            reg = _build_registry(params, cfg, slots, wtype, root)
            eng = ServingEngine(reg, router, max_batch=b,
                                min_bucket=min(8, b), auto_flush=False)
            eng.warmup()
            res = _replay(eng, histories, arrivals, order, max_wait,
                          requests // 2,
                          _make_swap(reg, wtype, slots, params2, cfg, root))
            if res["swaps"] < 1:
                raise AssertionError("hot-swap never observed during replay")
            rows.append((wtype, b, res))
            print(f"  {wtype:>5} bucket {b:>4}: {res['fps']:>10.0f} fc/s  "
                  f"p50 {res['p50_ms']:7.2f} ms  p99 {res['p99_ms']:7.2f} ms"
                  f"  fill {res['fill']:.2f}  swaps {res['swaps']}  "
                  f"roofline {res['fps']/bound:.1e}")

    print(f"[serving] zero steady-state recompiles on all "
          f"{len(rows)} configs (jit-cache probe), hot-swap observed on all")
    print(f"[serving] v5e single-chip roofline bound: {bound:.2e} fc/s "
          f"({flops_fc:.0f} FLOPs/forecast, {cfg.num_params()} params)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--consumers", type=int, default=64)
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--buckets", default="64,256",
                    help="comma-separated max-batch bucket sizes to sweep")
    ap.add_argument("--weights", default="fp32,int8",
                    help="comma-separated weight kinds: fp32,int8")
    ap.add_argument("--clusters", type=int, default=2,
                    help="route over k cluster models (0/1 = single global)")
    ap.add_argument("--days", type=int, default=14)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--max-wait", type=float, default=0.02,
                    help="flush deadline: max simulated queueing seconds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--state", default="CA")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized trace (300 requests, one bucket)")
    main(**vars(ap.parse_args()))
