"""Benchmark runner — one section per paper table/figure + framework benches.

  PYTHONPATH=src python -m benchmarks.run [--only NAME]

Sections:
  clustering   Tables 2 & 3 (K-means clustering vs global vs SARIMA)
  ewmse        Table 4 + Fig. 3 (MSE vs EW-MSE per horizon × state)
  lstm_vs_gru  Fig. 4 (architecture × loss × state)
  beta         Fig. 5 (EW-MSE β ablation)
  scalability  §5.4 (generalization to large unseen populations)
  scaling_pipeline  client-count axis with the delta-transform stack
               (clip + DP noise + int8 quantize) and hierarchical
               edge→region→cloud aggregation: rounds/s + MAPE delta
  pacing_semi_sync  semi-synchronous buffered rounds vs the sync baseline
               under lognormal stragglers: simulated wall-clock to the
               common target loss + held-out MAPE
  edge         §5.5 (edge-cluster envelope, simulated + per-level link
               budgets)
  serving      §5.4 deployment path: forecasts/s + p50/p99 latency of the
               padded-bucket serving engine under a replayed Poisson trace,
               fp32 vs int8 weights, cluster routing + mid-replay hot-swap
  kernels      Pallas kernels vs references
  roofline     §Roofline table from the dry-run artifacts
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (bench_beta, bench_clustering, bench_edge,
                        bench_ew_ce, bench_ewmse, bench_kernels,
                        bench_lstm_vs_gru, bench_roofline,
                        bench_scalability, bench_serving)

def _scaling_pipeline():
    """Client-count axis under the full pipeline: DP clip + noise + int8
    quantized deltas, aggregated edge→region→cloud (2-D mesh)."""
    return bench_scalability.main(
        clients=1000, rounds=3, clients_per_round=16, days=60,
        dp_clip=1.0, dp_noise=0.5, quantize=8, hier=True)


def _pacing_semi_sync():
    """Round-pacing axis: semi-sync buffered rounds (over-select 1.5x,
    flush at m, staleness alpha 0.5) vs sync under lognormal stragglers."""
    return bench_scalability.main(
        clients=500, rounds=12, clients_per_round=16, days=60,
        mode="semi_sync", stragglers="lognormal")


SECTIONS = [
    ("kernels", bench_kernels.main),
    ("roofline", bench_roofline.main),
    ("edge", bench_edge.main),
    ("serving", bench_serving.main),
    ("clustering", bench_clustering.main),
    ("ewmse", bench_ewmse.main),
    ("ew_ce_transfer", bench_ew_ce.main),
    ("lstm_vs_gru", bench_lstm_vs_gru.main),
    ("beta", bench_beta.main),
    ("scalability", bench_scalability.main),
    ("scaling_pipeline", _scaling_pipeline),
    ("pacing_semi_sync", _pacing_semi_sync),
]


def main() -> None:
    import os
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    from benchmarks._common import scale
    print(f"bench scale = {os.environ.get('REPRO_BENCH_SCALE', 'default')} "
          f"{scale()}  (REPRO_BENCH_SCALE=fast|default|paper)")
    failures = []
    for name, fn in SECTIONS:
        if args.only and name != args.only:
            continue
        print(f"\n{'='*72}\n== bench: {name}\n{'='*72}")
        t0 = time.time()
        try:
            fn()
            print(f"== {name} done in {time.time()-t0:.0f}s")
        except Exception:                                # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == '__main__':
    main()
