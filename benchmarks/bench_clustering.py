"""Paper Tables 2 & 3 — impact of K-means clustering + SARIMA comparison.

Cluster-specific federated LSTM models (F^C1..F^C4) vs the single global
FedAvg model (F^A) vs per-cluster SARIMA (S^Ci), evaluated on held-out
buildings assigned to clusters by nearest centroid.
"""
from __future__ import annotations

import numpy as np

from benchmarks._common import run_fl, scale
from repro.core import sarima
from repro.data import synthetic


def sarima_cluster_accuracy(state, ids, days, n_eval=3):
    """Mean SARIMA rolling-forecast accuracy over a few buildings (§4.3)."""
    accs = []
    for b in ids[:n_eval]:
        s = synthetic.generate_buildings(state, [b], days=min(days, 40))[0]
        try:
            pred, actual = sarima.rolling_forecast(s, lookahead=4,
                                                   fit_days=30,
                                                   horizon_days=3)
            ape = np.abs((actual - pred) / np.maximum(np.abs(actual), 1e-2))
            accs.append(100 - 100 * ape.mean())
        except Exception:                                # noqa: BLE001
            continue
    return float(np.mean(accs)) if accs else float("nan")


def main(state="CA"):
    rows = []
    res = run_fl(state=state, cell="lstm", loss="mse", clusters=4)
    print("# Table 2/3 reproduction — clustering impact "
          f"({scale()['clients']} train buildings, {state})")
    print("model,cluster,accuracy_pct")
    for cid, met in sorted(res["per_cluster"].items()):
        print(f"F^C{cid},{cid},{met['accuracy']:.2f}")
        rows.append((f"F^C{cid}", met["accuracy"]))
    print(f"F^A(global),all,{res['global_accuracy']:.2f}")
    avg_c = res["avg_of_clusters"]
    print(f"avg_of_clusters,all,{avg_c:.2f}")
    rows.append(("F^A", res["global_accuracy"]))
    rows.append(("avg_clusters", avg_c))

    sar = sarima_cluster_accuracy(state, list(range(10_000, 10_006)),
                                  scale()["days"])
    print(f"SARIMA,sample,{sar:.2f}")
    rows.append(("SARIMA", sar))
    delta = avg_c - res["global_accuracy"]
    print(f"# paper finding: clustering ≥ global (Δ here = {delta:+.2f} pp; "
          f"paper Δ = +0.38 pp)")
    return rows


if __name__ == "__main__":
    main()
